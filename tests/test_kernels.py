"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.segment_agg.ops import (
    compact_gather_layout, dst_aligned_layout, fused_edge_mlp_agg,
    pick_block_sizes)
from repro.kernels.segment_agg.ref import edge_mlp_agg_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Skv, Hq, Hkv, D, causal, window, softcap, bq, bk
    (1, 128, 128, 2, 2, 64, True, 0, None, 32, 32),
    (2, 96, 96, 4, 2, 32, True, 0, None, 32, 16),
    (1, 160, 160, 2, 1, 64, True, 48, None, 32, 32),
    (1, 64, 64, 2, 2, 128, False, 0, 30.0, 32, 32),
    (1, 72, 72, 1, 1, 16, True, 0, None, 16, 16),   # non-multiple seq
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Sq, Skv, Hq, Hkv, D, caus, win, cap, bq, bk = case
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    out = flash_attention(q, k, v, scale=D ** -0.5, causal=caus, window=win,
                          softcap=cap, block_q=bq, block_k=bk, interpret=True)
    G = Hq // Hkv
    ref = attention_ref(
        q.transpose(0, 2, 1, 3),
        jnp.repeat(k.transpose(0, 2, 1, 3), G, 1),
        jnp.repeat(v.transpose(0, 2, 1, 3), G, 1),
        scale=D ** -0.5, causal=caus, window=win, softcap=cap,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# fused edge-MLP + segment aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_segment_agg_random_graphs(seed, dtype):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 90))
    E = int(rng.integers(50, 400))
    fin, hid = 24, 16
    block_n, block_e = 16, 32
    dst = rng.integers(0, n, E)
    feats = rng.normal(size=(E, fin)).astype(np.float32)
    wgt = rng.uniform(0.5, 1.0, E).astype(np.float32)
    w1 = rng.normal(size=(fin, hid)).astype(np.float32) * 0.2
    b1 = rng.normal(size=(hid,)).astype(np.float32) * 0.1
    w2 = rng.normal(size=(hid, hid)).astype(np.float32) * 0.2
    b2 = rng.normal(size=(hid,)).astype(np.float32) * 0.1

    layout = dst_aligned_layout(dst, n, block_n, block_e)
    e_new, agg = fused_edge_mlp_agg(
        jnp.asarray(feats, dtype), jnp.asarray(dst, jnp.int32), jnp.asarray(wgt),
        jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2), jnp.asarray(b2),
        layout, n_nodes=n, block_n=block_n, block_e=block_e, interpret=True)

    e_ref, agg_ref = edge_mlp_agg_ref(
        jnp.asarray(feats), jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2),
        jnp.asarray(b2), jnp.asarray(dst), jnp.asarray(wgt), n)
    np.testing.assert_allclose(np.asarray(e_new), np.asarray(e_ref),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(agg)[:n], np.asarray(agg_ref),
                               rtol=1e-4, atol=1e-4)


def test_segment_agg_mesh_graph_low_waste():
    """Bounded-degree SEM mesh graphs tile tightly under dst alignment."""
    from repro.core.mesh_gen import box_mesh, mesh_graph_edges, undirected_to_directed
    m = box_mesh((4, 4, 2), p=3)
    e = undirected_to_directed(mesh_graph_edges(m))
    layout = dst_aligned_layout(e[:, 1], m.n_nodes, 128, 256)
    assert layout["waste"] < 0.6


@pytest.mark.parametrize("seed", range(3))
def test_dst_aligned_layout_properties(seed):
    """Vectorized layout pass: every in-range edge appears exactly once, in
    the node block owning its dst; out-of-range (sentinel) edges are dropped;
    dstl is the block-local dst."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 70))
    E = int(rng.integers(20, 300))
    block_n, block_e = 16, 8
    dst = rng.integers(0, n + 5, E)          # some >= n -> dropped
    layout = dst_aligned_layout(dst, n, block_n, block_e)
    perm, dstl = layout["perm"], layout["dstl"]
    kept = np.sort(perm[perm >= 0])
    np.testing.assert_array_equal(kept, np.nonzero(dst < n)[0])
    for b in range(layout["n_node_blocks"]):
        sel = perm[b][perm[b] >= 0]
        assert ((dst[sel] >= b * block_n) & (dst[sel] < (b + 1) * block_n)).all()
        np.testing.assert_array_equal(dstl[b][perm[b] >= 0],
                                      dst[sel] - b * block_n)
    assert (dstl[perm < 0] == 0).all()
    assert 0.0 <= layout["waste"] < 1.0


@pytest.mark.parametrize("seed", range(3))
def test_compact_gather_layout_properties(seed):
    """Compact layout pass: every in-range edge appears exactly once, edges
    are dst-sorted across the flat tile list, per-slot src/dst match the
    edge arrays, only the final tile carries padding, and padding slots are
    zeroed."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 70))
    E = int(rng.integers(20, 300))
    block_e = 16
    src = rng.integers(0, n, E)
    dst = rng.integers(0, n + 5, E)          # some >= n -> dropped
    lay = compact_gather_layout(src, dst, n, block_e)
    perm = lay["perm"].reshape(-1)
    kept = np.sort(perm[perm >= 0])
    np.testing.assert_array_equal(kept, np.nonzero(dst < n)[0])
    assert lay["n_edges"] == kept.size
    assert lay["perm"].shape == (lay["n_tiles"], block_e)
    # only tail padding: all real slots come before the first -1
    n_real = int((perm >= 0).sum())
    assert (perm[:n_real] >= 0).all() and (perm[n_real:] == -1).all()
    # dst-sorted; src/dst recorded per slot; padding slots zeroed
    real = perm[perm >= 0]
    assert (np.diff(dst[real]) >= 0).all()
    np.testing.assert_array_equal(lay["src"].reshape(-1)[:n_real], src[real])
    np.testing.assert_array_equal(lay["dst"].reshape(-1)[:n_real], dst[real])
    assert (lay["src"].reshape(-1)[n_real:] == 0).all()
    assert (lay["dst"].reshape(-1)[n_real:] == 0).all()


def test_pick_block_sizes_table_and_env(monkeypatch):
    """Autotune helper: table lookup keyed on hidden/dtype/backend, env
    override wins."""
    bn, be = pick_block_sizes(16, jnp.float32, backend="cpu")
    assert bn > 0 and be > 0
    # wider hidden never increases the edge tile (VMEM scratch bound)
    _, be_wide = pick_block_sizes(512, jnp.float32, backend="cpu")
    assert be_wide <= be
    # bf16 rows are half the bytes -> deeper tiles
    _, be16 = pick_block_sizes(16, jnp.bfloat16, backend="cpu")
    assert be16 == 2 * be
    monkeypatch.setenv("REPRO_SEG_BLOCKS", "64,48")
    assert pick_block_sizes(16, jnp.float32, backend="cpu") == (64, 48)


def _random_nmp_case(seed, n_hidden=2, final_layernorm=True):
    from repro import nn
    rng = np.random.default_rng(seed)
    n, E, H = int(rng.integers(20, 60)), int(rng.integers(40, 200)), 8
    src = rng.integers(0, n, E)
    dst = rng.integers(0, n, E)
    emask = (rng.uniform(size=E) > 0.1).astype(np.float32)
    einv = rng.uniform(0.3, 1.0, E).astype(np.float32) * emask
    x = jnp.asarray(rng.normal(size=(n, H)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(E, H)), jnp.float32)
    params = nn.init_mlp(jax.random.PRNGKey(seed), 3 * H, [H] * n_hidden, H,
                         final_layernorm=final_layernorm)
    meta = dict(edge_src=jnp.asarray(src, jnp.int32),
                edge_dst=jnp.asarray(dst, jnp.int32),
                edge_mask=jnp.asarray(emask), edge_inv_mult=jnp.asarray(einv))
    return n, src, dst, emask, x, e, params, meta


def _nmp_paths(n, src, dst, emask, meta, params, block_e=32):
    """(xla_path, fused_path) closures over a compact layout of the case."""
    from repro.graph import segment
    from repro import nn
    from repro.kernels.segment_agg.ops import fused_nmp_edge_agg

    layout = compact_gather_layout(src, np.where(emask > 0, dst, n), n, block_e)
    perm = jnp.asarray(layout["perm"])
    seg_src = jnp.asarray(layout["src"])
    seg_dst = jnp.asarray(layout["dst"])

    def xla_path(p, x, e, precision=None):
        xi = segment.gather(x, meta["edge_src"])
        xj = segment.gather(x, meta["edge_dst"])
        e_new = (e + nn.mlp(p, jnp.concatenate([xi, xj, e], -1),
                            precision=precision)) \
            * meta["edge_mask"][:, None]
        agg = segment.segment_sum(e_new * meta["edge_inv_mult"][:, None],
                                  meta["edge_dst"], n)
        return e_new, agg

    def fused_path(p, x, e, precision="fp32"):
        return fused_nmp_edge_agg(
            x, e, p, perm, seg_src, seg_dst, meta["edge_mask"],
            meta["edge_inv_mult"], interpret=True, precision=precision)

    return xla_path, fused_path


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("n_hidden,ln", [(2, True), (0, False)])
def test_fused_nmp_forward_and_custom_vjp_gradcheck(seed, n_hidden, ln):
    """The custom-VJP fused op (scalar-prefetch DMA gathers) matches jax.grad
    of the XLA reference path (interpret mode), for deep+LN and single-layer
    no-LN edge MLPs."""
    n, src, dst, emask, x, e, params, meta = _random_nmp_case(seed, n_hidden, ln)
    xla_path, fused_path = _nmp_paths(n, src, dst, emask, meta, params)

    o_x = jax.jit(xla_path)(params, x, e)
    o_f = jax.jit(fused_path)(params, x, e)
    for a, b in zip(o_x, o_f):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-5)

    def scalar(fn):
        def L(p, x, e):
            en, ag = fn(p, x, e)
            return jnp.sum(jnp.sin(en)) + jnp.sum(ag * jnp.cos(ag))
        return L

    g_x = jax.jit(jax.grad(scalar(xla_path), argnums=(0, 1, 2)))(params, x, e)
    g_f = jax.jit(jax.grad(scalar(fused_path), argnums=(0, 1, 2)))(params, x, e)
    for a, b in zip(jax.tree.leaves(g_x), jax.tree.leaves(g_f)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=2e-4)


def test_fused_nmp_bf16_precision_close_but_not_bitstable():
    """precision="bf16" (bf16 matmul operands, fp32 accumulation): the fused
    kernel matches the XLA path running the *same* bf16 truncation policy to
    near-fp32 tolerance (only the fp32 accumulation order differs), and
    tracks the untruncated fp32 reference to bf16 tolerance."""
    n, src, dst, emask, x, e, params, meta = _random_nmp_case(0)
    xla_path, fused_path = _nmp_paths(n, src, dst, emask, meta, params)

    o_x32 = jax.jit(xla_path)(params, x, e)
    o_x16 = jax.jit(lambda p, x, e: xla_path(p, x, e, precision="bf16"))(
        params, x, e)
    o_f16 = jax.jit(lambda p, x, e: fused_path(p, x, e, precision="bf16"))(
        params, x, e)
    for a, b in zip(o_x16, o_f16):                   # same truncation: tight
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=1e-3)
    for a, b in zip(o_x32, o_f16):                   # vs fp32: bf16 tolerance
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=5e-2, atol=5e-2)

    def loss(fn, **kw):
        # linear functional with non-trivial weights: the curvature-free
        # probe keeps bf16 value differences from amplifying through the
        # test loss's second derivative
        def L(p, x, e):
            en, ag = fn(p, x, e, **kw)
            ce = jnp.cos(jnp.arange(en.size, dtype=jnp.float32)).reshape(en.shape)
            ca = jnp.sin(jnp.arange(ag.size, dtype=jnp.float32)).reshape(ag.shape)
            return jnp.sum(en * ce) + jnp.sum(ag * ca)
        return L

    # weight grads flow through a bf16-preferred transpose dot on both
    # paths (JAX's dot_general transpose rule), so they can land on
    # adjacent bf16 grid points — compare at bf16-ulp tolerance
    g_x16 = jax.jit(jax.grad(loss(xla_path, precision="bf16")))(params, x, e)
    g_f16 = jax.jit(jax.grad(loss(fused_path, precision="bf16")))(params, x, e)
    for a, b in zip(jax.tree.leaves(g_x16), jax.tree.leaves(g_f16)):
        a = np.asarray(a)
        np.testing.assert_allclose(
            np.asarray(b), a, rtol=1e-2, atol=1e-2 * max(1.0, np.abs(a).max()))

    with pytest.raises(ValueError, match="precision"):
        jax.jit(lambda p, x, e: fused_path(p, x, e, precision="fp8"))(
            params, x, e)


def test_fused_nmp_isolated_nodes_and_all_padding_tile():
    """Degenerate shapes: a graph whose node set includes isolated
    (degree-0) nodes gets zero aggregate rows there, and a tile list padded
    with an entirely-empty tile (the cross-rank tile-count padding the
    stacked layout produces) contributes nothing."""
    from repro.kernels.segment_agg.ops import fused_nmp_edge_agg
    from repro.graph import segment
    from repro import nn

    rng = np.random.default_rng(3)
    n, E, H, block_e = 24, 40, 8, 16
    # every edge lands in the first third of the nodes -> the rest isolated
    src = rng.integers(0, n // 3, E)
    dst = rng.integers(0, n // 3, E)
    emask = np.ones(E, np.float32)
    einv = rng.uniform(0.3, 1.0, E).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(n, H)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(E, H)), jnp.float32)
    params = nn.init_mlp(jax.random.PRNGKey(0), 3 * H, [H] * 2, H)

    lay = compact_gather_layout(src, dst, n, block_e)
    # append an all-padding tile, as the stacked per-rank layout does when
    # another rank has more edge tiles
    def pad_tile(a, fill):
        return np.concatenate([a, np.full((1, block_e), fill, a.dtype)])
    perm = jnp.asarray(pad_tile(lay["perm"], -1))
    seg_src = jnp.asarray(pad_tile(lay["src"], 0))
    seg_dst = jnp.asarray(pad_tile(lay["dst"], 0))

    e_f, a_f = jax.jit(lambda p, x, e: fused_nmp_edge_agg(
        x, e, p, perm, seg_src, seg_dst, jnp.asarray(emask),
        jnp.asarray(einv), interpret=True))(params, x, e)

    xi = segment.gather(x, jnp.asarray(src, jnp.int32))
    xj = segment.gather(x, jnp.asarray(dst, jnp.int32))
    e_ref = (e + nn.mlp(params, jnp.concatenate([xi, xj, e], -1)))
    a_ref = segment.segment_sum(e_ref * jnp.asarray(einv)[:, None],
                                jnp.asarray(dst, jnp.int32), n)
    np.testing.assert_allclose(np.asarray(e_f), np.asarray(e_ref),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a_f), np.asarray(a_ref),
                               rtol=1e-4, atol=1e-5)
    # isolated nodes: exactly zero aggregate
    assert np.all(np.asarray(a_f)[n // 3:] == 0.0)
    # gradients survive the all-padding tile and isolated rows
    g = jax.jit(jax.grad(lambda xx: fused_nmp_edge_agg(
        xx, e, params, perm, seg_src, seg_dst, jnp.asarray(emask),
        jnp.asarray(einv), interpret=True)[1].sum()))(x)
    assert np.isfinite(np.asarray(g)).all()


@pytest.mark.slow
def test_segment_agg_size_sweep_scaling():
    """The kernel_bench size sweep runs end to end (interpret mode, small
    sizes) and demonstrates the O(E·N) -> O(E) crossover: the DMA-gather
    FLOP model is size-independent per edge while the retired one-hot
    model's per-edge cost grows with N; fused-vs-xla consistency holds at
    every size."""
    from benchmarks.kernel_bench import segment_agg_size_sweep

    rows = segment_agg_size_sweep(sizes=(512, 2048), hidden=8)
    assert [r["n_nodes"] for r in rows] == [512, 2048]
    for r in rows:
        assert r["gather_mode"] == "prefetch_dma"
        assert r["max_abs_err"] < 1e-3
        assert "fused_interpret_us" in r or "fused_us" in r
    # O(E) gather: per-edge FLOPs flat in N; one-hot model grows ~linearly
    assert rows[0]["flops_per_edge_dma"] == rows[1]["flops_per_edge_dma"]
    growth = rows[1]["flops_per_edge_onehot"] / rows[0]["flops_per_edge_onehot"]
    assert growth > 2.0


# ---------------------------------------------------------------------------
# embedding bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 4, 64, 32), (16, 1, 256, 16), (4, 8, 128, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(shape, dtype):
    B, H, V, D = shape
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype)
    idx = jnp.asarray(rng.integers(0, V, (B, H)), jnp.int32)
    out = embedding_bag(table, idx, interpret=True)
    ref = embedding_bag_ref(table, idx)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])
