"""LM transformer smoke + correctness tests (reduced configs, 1 device)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import deepseek_v2_236b, dbrx_132b, llama3_2_3b, granite_34b, gemma2_2b
from repro.models.transformer.model import (
    ParallelCtx, decode_step, forward, init_transformer, lm_loss,
    prefill_step,
)
from repro.models.transformer.moe import moe_ffn, moe_ffn_reference, init_moe
from repro.models.transformer.config import MoEConfig
from repro.sharding import split_tree

ARCHS = {
    "deepseek": deepseek_v2_236b,
    "dbrx": dbrx_132b,
    "llama": llama3_2_3b,
    "granite": granite_34b,
    "gemma2": gemma2_2b,
}


@pytest.fixture(scope="module")
def ctx():
    return ParallelCtx.single_device()


def _setup(mod):
    cfg = mod.smoke_config()
    tree = init_transformer(jax.random.PRNGKey(0), cfg)
    params, _ = split_tree(tree, {})
    return cfg, params


@pytest.mark.parametrize("name", list(ARCHS))
def test_forward_shapes_and_finite(name, ctx):
    cfg, params = _setup(ARCHS[name])
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, aux = jax.jit(lambda p, t: forward(p, t, cfg, ctx))(params, tokens)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", list(ARCHS))
def test_train_loss_and_grad(name, ctx):
    cfg, params = _setup(ARCHS[name])
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
    targets = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, cfg.vocab)

    def loss_fn(p):
        return lm_loss(p, tokens, targets, cfg, ctx)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    # sane magnitude: CE near log(V) at init
    assert 0.2 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)
    gnorm = float(jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                               for g in jax.tree.leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ["llama", "gemma2", "deepseek"])
def test_prefill_then_decode_matches_forward(name, ctx):
    """Score a sequence with (prefill + decode steps) vs the train forward."""
    cfg, params = _setup(ARCHS[name])
    if name == "deepseek":
        # the 512-dim MLA latent dot amplifies bf16 cache rounding; compare
        # the math in fp32 (production serving keeps bf16 caches)
        cfg = cfg.with_(param_dtype=jnp.float32, cache_dtype=jnp.float32)
        tree = init_transformer(jax.random.PRNGKey(0), cfg)
        params, _ = split_tree(tree, {})
    B, S_pre, S_total = 1, 8, 12
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S_total), 0, cfg.vocab)

    logits_all, _ = jax.jit(lambda p, t: forward(p, t, cfg, ctx))(params, tokens)

    last, cache = jax.jit(lambda p, t: prefill_step(p, t, cfg, ctx, capacity=S_total))(
        params, tokens[:, :S_pre])
    np.testing.assert_allclose(
        np.asarray(last, np.float32), np.asarray(logits_all[:, S_pre - 1], np.float32),
        rtol=2e-2, atol=2e-2)

    dec = jax.jit(lambda p, c, t, n: decode_step(p, c, t, n, cfg, ctx))
    for i in range(S_pre, S_total):
        logits_i, cache = dec(params, cache, tokens[:, i:i + 1], jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits_i[:, 0], np.float32), np.asarray(logits_all[:, i], np.float32),
            rtol=2e-2, atol=2e-2,
            err_msg=f"decode step {i} mismatch")


def test_moe_matches_reference_when_no_drops(ctx):
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=4.0)
    d = 16
    params_tree = init_moe(jax.random.PRNGKey(0), d, cfg, "swiglu", jnp.float32)
    params, _ = split_tree(params_tree, {})
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d), jnp.float32)
    y, aux = jax.jit(lambda p, x: moe_ffn(p, x, cfg, "swiglu", ctx.mesh, ctx.batch_axes))(params, x)
    y_ref = moe_ffn_reference(params, x, cfg, "swiglu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_gemma_window_changes_output(ctx):
    cfg, params = _setup(ARCHS["gemma2"])
    cfg_glob = cfg.with_(window=None, window_pattern="none")
    tokens = jax.random.randint(jax.random.PRNGKey(5), (1, 24), 0, cfg.vocab)
    l1, _ = jax.jit(lambda p, t: forward(p, t, cfg, ctx))(params, tokens)
    l2, _ = jax.jit(lambda p, t: forward(p, t, cfg_glob, ctx))(params, tokens)
    # long-range tokens must differ once the window truncates context
    assert np.abs(np.asarray(l1[:, -1]) - np.asarray(l2[:, -1])).max() > 1e-4


def test_param_count_math():
    for name, mod in ARCHS.items():
        cfg = mod.smoke_config()
        tree = init_transformer(jax.random.PRNGKey(0), cfg)
        params, _ = split_tree(tree, {})
        actual = sum(int(x.size) for x in jax.tree.leaves(params))
        # analytic count ignores norms/routers; must be within 5%
        analytic = cfg.n_params()
        assert abs(actual - analytic) / analytic < 0.05, (name, actual, analytic)
