"""Resident inference engine: lifecycle, consistency, refusal, shutdown.

In-process (single device) coverage of ``repro.runtime.engine``; the CI
serve-smoke job re-runs the same contracts on real collectives via
``tests/drivers/serve_driver.py`` at 1 and 2 forced host devices.
"""
import numpy as np
import pytest
import jax

from repro.core import GNNConfig, NMPPlan, box_mesh, init_gnn, partition_mesh
from repro.core.distributed import shard_graph
from repro.core.graph_state import ShardedGraph
from repro.core.mesh_gen import taylor_green_velocity
from repro.core.partition import gather_node_features, scatter_node_outputs
from repro.ckpt import checkpoint as ckpt
from repro.launch.mesh import make_mesh
from repro.runtime.engine import (
    EngineConfig, EngineError, InferenceEngine, MeshMismatchError,
)
from repro.train.loop import TrainConfig, mesh_fingerprint_hash, \
    run_fingerprint
from repro.train.rollout import make_rollout_predict_fn

K = 2
DT = 0.05


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """One mesh + model + fingerprinted checkpoint shared by every test."""
    sem = box_mesh((3, 3, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    ckdir = tmp_path_factory.mktemp("serve") / "ck"
    fp = run_fingerprint(sem, partition_mesh(sem, (1, 1, 1)), cfg,
                         TrainConfig(), NMPPlan())
    # full training-shaped tree: the engine must restore ONLY params
    ckpt.save(ckdir, 0,
              {"params": params, "opt": {"m": np.zeros(4, np.float32)},
               "rng": np.zeros(2, np.uint32)},
              extra={"fingerprint": fp})
    return dict(sem=sem, cfg=cfg, params=params, ckdir=ckdir, fp=fp)


def snapshot(sem, step):
    return taylor_green_velocity(sem.coords,
                                 t=(step * DT) % 2.0).astype(np.float32)


def make_engine(served, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("rollout_steps", K)
    return InferenceEngine(served["ckdir"], served["cfg"],
                           EngineConfig(**kw))


# ---------------------------------------------------------------------------
# checkpoint contract


def test_engine_restores_params_only_from_training_checkpoint(served):
    eng = make_engine(served)
    for a, b in zip(jax.tree.leaves(eng.params),
                    jax.tree.leaves(served["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert eng.ckpt_step == 0
    assert eng.fingerprint["mesh_hash"] == served["fp"]["mesh_hash"]


def test_engine_refuses_unfingerprinted_checkpoint(served, tmp_path):
    ckdir = tmp_path / "bare"
    ckpt.save(ckdir, 0, {"params": served["params"]})
    with pytest.raises(EngineError, match="fingerprint"):
        InferenceEngine(ckdir, served["cfg"], EngineConfig())


def test_engine_refuses_model_config_mismatch(served):
    wrong = GNNConfig(hidden=16, n_mp_layers=2, mlp_hidden_layers=2)
    with pytest.raises(EngineError, match="hidden"):
        InferenceEngine(served["ckdir"], wrong, EngineConfig())


def test_engine_falls_back_past_corrupted_newest_step(served, tmp_path):
    ckdir = tmp_path / "corrupt"
    other = jax.tree.map(lambda a: np.asarray(a) + 1.0, served["params"])
    ckpt.save(ckdir, 0, {"params": served["params"]},
              extra={"fingerprint": served["fp"]})
    ckpt.save(ckdir, 1, {"params": other},
              extra={"fingerprint": served["fp"]})
    shard = ckdir / "step_0000000001" / "shard_0.npz"
    shard.write_bytes(shard.read_bytes()[:64])    # truncate after commit
    eng = InferenceEngine(ckdir, served["cfg"], EngineConfig())
    assert eng.ckpt_step == 0
    for a, b in zip(jax.tree.leaves(eng.params),
                    jax.tree.leaves(served["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_restore_partial_roundtrip_and_bad_prefix(served):
    template = jax.tree.map(np.asarray, served["params"])
    vals, manifest = ckpt.restore_partial(served["ckdir"], template, "params")
    for a, b in zip(jax.tree.leaves(vals), jax.tree.leaves(template)):
        assert np.array_equal(np.asarray(a), b)
    assert manifest["step"] == 0
    with pytest.raises(ValueError, match="params"):
        ckpt.restore_partial(served["ckdir"], template, "nonexistent")
    with pytest.raises(ValueError, match="template"):
        ckpt.restore_partial(served["ckdir"], {"lonely": np.zeros(3)},
                             "params")


# ---------------------------------------------------------------------------
# graph cache + mesh refusal


def test_register_mesh_caches_by_hash(served):
    eng = make_engine(served)
    h1 = eng.register_mesh(served["sem"])
    h2 = eng.register_mesh(served["sem"])
    assert h1 == h2 == mesh_fingerprint_hash(served["sem"])
    assert eng.stats["cache_builds"] == 1
    assert eng.stats["cache_hits"] == 1


def test_mesh_mismatch_refused_by_name(served):
    eng = make_engine(served)
    other = box_mesh((2, 2, 2), p=2)
    other_hash = mesh_fingerprint_hash(other)
    with pytest.raises(MeshMismatchError) as ei:
        eng.register_mesh(other)
    assert served["fp"]["mesh_hash"] in str(ei.value)
    assert other_hash in str(ei.value)
    with pytest.raises(MeshMismatchError):
        eng.submit(other_hash, snapshot(other, 0))


def test_submit_requires_registration_and_shape(served):
    eng = make_engine(served)
    h = mesh_fingerprint_hash(served["sem"])
    with pytest.raises(EngineError, match="register_mesh"):
        eng.submit(h, snapshot(served["sem"], 0))
    eng.register_mesh(served["sem"])
    with pytest.raises(EngineError, match="shape"):
        eng.submit(h, np.zeros((7, 3), np.float32))


# ---------------------------------------------------------------------------
# consistency contract


def test_streamed_output_bitwise_equals_offline_rollout_eval(served):
    sem, cfg, params = served["sem"], served["cfg"], served["params"]
    eng = make_engine(served, batch_slots=4)   # 5 requests -> padded batches
    h = eng.register_mesh(sem)
    eng.warmup()
    with eng:
        out = dict(eng.stream(h, lambda s: snapshot(sem, s), 5,
                              n_producers=2))
    assert len(out) == 5

    # independently built offline eval (same device count, batch=1)
    pg = partition_mesh(sem, (1, 1, 1))
    plan = NMPPlan.build(pg, "none", axis="graph")
    graph = ShardedGraph.build(pg, sem.coords, plan)
    mesh_dev = make_mesh((1, 1), ("data", "graph"))
    predict = make_rollout_predict_fn(mesh_dev, cfg, plan, K)
    gs = shard_graph(mesh_dev, graph)
    for step, res in out.items():
        xs = gather_node_features(pg, snapshot(sem, step))[None]
        preds = np.asarray(predict(params, xs, gs))[0]
        offline = np.stack([scatter_node_outputs(pg, preds[k])
                            for k in range(K)])
        assert np.array_equal(offline, res.preds), f"step {step}"
        assert res.preds.shape == (K, pg.n_global, cfg.node_out)
    assert eng.stats["padded_slots"] > 0   # padding really happened


def test_offline_reference_matches_submit(served):
    sem = served["sem"]
    eng = make_engine(served)
    h = eng.register_mesh(sem)
    eng.warmup()
    with eng:
        res = eng.submit(h, snapshot(sem, 3), step=3).result(timeout=60)
    assert np.array_equal(res.preds, eng.offline_reference(h, snapshot(sem, 3)))


# ---------------------------------------------------------------------------
# backpressure + shutdown


def test_submit_backpressure_bounded_queue(served):
    sem = served["sem"]
    eng = make_engine(served, max_pending=2)   # engine NOT started: queue fills
    h = eng.register_mesh(sem)
    futs = [eng.submit(h, snapshot(sem, s), step=s, timeout=1.0)
            for s in range(2)]
    with pytest.raises(EngineError, match="saturated|full"):
        eng.submit(h, snapshot(sem, 2), timeout=0.05)
    eng.warmup()
    eng.start()
    for s, fut in enumerate(futs):
        res = fut.result(timeout=60)
        assert res.step == s
    eng.close()


def test_close_fails_pending_and_refuses_submit(served):
    sem = served["sem"]
    eng = make_engine(served)
    h = eng.register_mesh(sem)
    fut = eng.submit(h, snapshot(sem, 0))      # never started -> still queued
    eng.close()
    with pytest.raises(EngineError, match="shut down"):
        fut.result(timeout=5)
    with pytest.raises(EngineError):
        eng.submit(h, snapshot(sem, 1))
    with pytest.raises(EngineError, match="already started|shut down"):
        eng.start()


def test_producer_death_terminates_engine_with_error(served):
    sem = served["sem"]
    eng = make_engine(served)
    h = eng.register_mesh(sem)
    eng.warmup()
    eng.start()

    def dying(step):
        if step >= 2:
            raise RuntimeError("injected producer death")
        return snapshot(sem, step)

    got = []
    with pytest.raises(EngineError, match="producer"):
        for step, _ in eng.stream(h, dying, 6, n_producers=1):
            got.append(step)
    assert got == [0, 1]          # drain-then-raise, end to end
    assert eng.closed
    with pytest.raises(EngineError, match="terminated"):
        eng.submit(h, snapshot(sem, 0))
