"""Optimizer / checkpoint / fault-tolerance / compression / sampler tests."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.runtime.fault_tolerance import ResilientConfig, run_resilient
from repro.runtime.straggler import StragglerMonitor
from repro.train.optimizer import (
    AdamWConfig, adamw_update, clip_by_global_norm, init_adamw, warmup_cosine,
)
from repro.train.grad_compress import compressed_psum, init_error_feedback
from repro.graph.sampler import CSRGraph, SampledBlock, sample_block
from repro.graph.datasets import powerlaw_graph


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_math():
    """One AdamW step vs hand-computed reference on a tiny problem."""
    cfg = AdamWConfig(schedule=lambda s: jnp.asarray(0.1), b1=0.9, b2=0.99,
                      eps=1e-8, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, 0.25])}
    state = init_adamw(params, cfg)
    new_p, new_s, info = adamw_update(grads, state, params, cfg)
    g = np.array([0.5, 0.25])
    m = 0.1 * g
    v = 0.01 * g ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expect = np.array([1.0, -2.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-6)
    assert int(new_s["step"]) == 1


def test_adamw_weight_decay_mask():
    cfg = AdamWConfig(schedule=lambda s: jnp.asarray(0.0), weight_decay=0.1,
                      clip_norm=None)
    # zero LR -> only decay matters; with lr=0 nothing moves. Use lr>0, g=0:
    cfg = AdamWConfig(schedule=lambda s: jnp.asarray(1.0), weight_decay=0.1,
                      clip_norm=None)
    params = {"dense": {"w": jnp.ones(3)}, "ln": {"g": jnp.ones(3)}}
    grads = jax.tree.map(jnp.zeros_like, params)
    state = init_adamw(params, cfg)
    new_p, _, _ = adamw_update(grads, state, params, cfg)
    assert np.all(np.asarray(new_p["dense"]["w"]) < 1.0)      # decayed
    np.testing.assert_allclose(np.asarray(new_p["ln"]["g"]), 1.0)  # masked


def test_clip_and_schedule():
    g = {"w": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(clipped["w"]), [0.6, 0.8], rtol=1e-6)
    sched = warmup_cosine(1.0, 10, 110, final_frac=0.0)
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-6)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(schedule=lambda s: jnp.asarray(0.1), clip_norm=1.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_adamw(params, cfg)
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


# ---------------------------------------------------------------------------
# checkpointing + fault tolerance
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.asarray([1.5]), "step": jnp.asarray(7)}}
    ckpt.save(tmp_path, 3, tree)
    ckpt.save(tmp_path, 7, jax.tree.map(lambda x: x + 1, tree))
    assert ckpt.latest_step(tmp_path) == 7
    restored, manifest = ckpt.restore(tmp_path, tree)
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]) + 1)
    assert manifest["step"] == 7
    ckpt.prune(tmp_path, keep=1)
    assert ckpt.latest_step(tmp_path) == 7
    with pytest.raises(Exception):
        ckpt.restore(tmp_path, tree, step=3)


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in (0, 5, 10):
        saver.save(s, {"x": jnp.full((4,), float(s))})
    saver.wait()
    restored, m = ckpt.restore(tmp_path, {"x": jnp.zeros(4)})
    np.testing.assert_allclose(np.asarray(restored["x"]), 10.0)


def test_failure_injection_recovers_bitwise(tmp_path):
    """Kill at step N, restart, final state identical to an uninterrupted run."""
    def init_state():
        return {"w": jnp.zeros(4), "step": jnp.asarray(0)}

    def step_fn(state, batch):
        w = state["w"] + batch
        return {"w": w, "step": state["step"] + 1}, {"loss": float(w.sum())}

    def batch_fn(step):
        return jnp.full((4,), float(step % 7) * 0.25)

    cfg = ResilientConfig(ckpt_dir=str(tmp_path / "ft"), ckpt_every=5,
                          max_restarts=2)
    state_f, hist = run_resilient(init_state, step_fn, batch_fn, 23, cfg,
                                  inject_failure_at=13)
    assert hist["restarts"] == 1

    # uninterrupted reference
    ref = init_state()
    for s in range(23):
        ref, _ = step_fn(ref, batch_fn(s))
    np.testing.assert_array_equal(np.asarray(state_f["w"]), np.asarray(ref["w"]))
    assert int(state_f["step"]) == 23


def test_straggler_monitor_detects_outliers():
    mon = StragglerMonitor(warmup_steps=5)
    for s in range(30):
        ev = mon.observe(s, 0.1 if s != 20 else 1.5)
        if s == 20:
            assert ev is not None
    assert len(mon.events) == 1
    assert mon.mean < 0.2  # outlier excluded from EWMA


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compressed_psum_error_feedback():
    mesh = jax.make_mesh((1,), ("d",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import PartitionSpec as P

    g = {"w": jnp.asarray([[0.5, -0.25], [0.1, 0.9]])}
    e = init_error_feedback(g)

    def f(g, e):
        return compressed_psum(g, e, ("d",), 1)

    out, err = jax.shard_map(f, mesh=mesh, in_specs=(P(), P()),
                             out_specs=(P(), P()), check_vma=False)(g, e)
    # one-shot quantization error bounded by scale/2
    scale = 0.9 / 127
    assert float(jnp.abs(out["w"] - g["w"]).max()) <= scale
    # error feedback: quantized + error == original
    np.testing.assert_allclose(np.asarray(out["w"] + err["w"]),
                               np.asarray(g["w"]), rtol=1e-6)
    # accumulated over steps, mean compressed gradient -> true gradient
    acc = jnp.zeros_like(g["w"])
    err_state = init_error_feedback(g)
    for _ in range(64):
        out, err_state = jax.shard_map(f, mesh=mesh, in_specs=(P(), P()),
                                       out_specs=(P(), P()), check_vma=False)(g, err_state)
        acc = acc + out["w"]
    np.testing.assert_allclose(np.asarray(acc / 64), np.asarray(g["w"]),
                               rtol=0.02, atol=1e-4)


# ---------------------------------------------------------------------------
# neighbor sampler
# ---------------------------------------------------------------------------

def test_sampler_shapes_and_validity():
    edges = powerlaw_graph(500, avg_deg=8, seed=3)
    g = CSRGraph.from_edges(500, edges)
    rng = np.random.default_rng(0)
    seeds = rng.choice(500, 16, replace=False)
    block = sample_block(g, seeds, (5, 3), rng)
    n_pad, e_pad = SampledBlock.pad_sizes(16, (5, 3))
    assert block.node_ids.shape == (n_pad,)
    assert block.edge_src.shape == (e_pad,)
    # every sampled edge is a real graph edge
    eset = {(int(a), int(b)) for a, b in edges}
    m = block.edge_mask > 0
    for s, d in zip(block.edge_src[m], block.edge_dst[m]):
        gs, gd = int(block.node_ids[s]), int(block.node_ids[d])
        assert (gs, gd) in eset
    # seeds occupy the first rows
    np.testing.assert_array_equal(block.node_ids[:16], seeds)
