"""Unit tests for scripts/bench_gate.py gate logic (no benchmarks run).

The segment-agg tests pin the ROADMAP carry-over fix: the strict compiled
gate must FAIL when ``fused_us`` is present in both runs and regresses,
and must say "compiled gate SKIPPED (interpret-only host)" explicitly when
it cannot fire — for years of CPU-only CI the skip was silent and nobody
noticed the compiled gate had never run once.
"""
import importlib.util
import os

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_spec = importlib.util.spec_from_file_location(
    "bench_gate", os.path.join(_ROOT, "scripts", "bench_gate.py"))
bench_gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_gate)


# ---------------------------------------------------------------------------
# compiled segment-agg gate


def test_compiled_gate_fails_on_fused_regression(capsys):
    payload = {"fused_us": 200.0, "xla_us": 50.0}
    base = {"fused_us": 100.0, "xla_us": 50.0}
    assert not bench_gate.gate_segment_agg(payload, base, 0.25)
    assert "REGRESSION" in capsys.readouterr().out


def test_compiled_gate_passes_within_allowance(capsys):
    payload = {"fused_us": 110.0, "xla_us": 50.0}
    base = {"fused_us": 100.0, "xla_us": 50.0}
    assert bench_gate.gate_segment_agg(payload, base, 0.25)
    assert "compiled gate ok" in capsys.readouterr().out


def test_interpret_only_host_reports_skip_explicitly(capsys):
    """CPU CI path: no fused_us anywhere — the log must state the compiled
    gate was SKIPPED and why, then still run the loose ratio gate."""
    payload = {"fused_interpret_us": 1000.0, "xla_us": 100.0}
    base = {"fused_interpret_us": 900.0, "xla_us": 100.0}
    assert bench_gate.gate_segment_agg(payload, base, 0.25)
    out = capsys.readouterr().out
    assert "compiled gate SKIPPED (interpret-only host)" in out
    assert "ratio" in out


def test_interpret_ratio_gate_still_fails_on_blowup(capsys):
    payload = {"fused_interpret_us": 10000.0, "xla_us": 100.0}
    base = {"fused_interpret_us": 1000.0, "xla_us": 100.0}
    assert not bench_gate.gate_segment_agg(payload, base, 0.25)
    out = capsys.readouterr().out
    assert "compiled gate SKIPPED (interpret-only host)" in out
    assert "REGRESSION" in out


def test_compiled_run_without_compiled_baseline_reports_skip(capsys):
    """Accelerator run vs interpret-only baseline: the strict gate cannot
    compare — the skip must name the missing compiled baseline."""
    payload = {"fused_us": 100.0, "fused_interpret_us": 1000.0,
               "xla_us": 100.0}
    base = {"fused_interpret_us": 1000.0, "xla_us": 100.0}
    assert bench_gate.gate_segment_agg(payload, base, 0.25)
    assert "compiled gate SKIPPED (no compiled baseline)" \
        in capsys.readouterr().out


# ---------------------------------------------------------------------------
# serve gate


def _serve_payload(**over):
    payload = {
        "cases": [{"batch_slots": 4, "latency_ms_p50": 40.0,
                   "latency_ms_p95": 55.0, "latency_ms_mean": 42.0,
                   "req_per_s": 170.0, "batches": 6, "padded_slots": 0}],
        "graph_cache": {"cold_build_ms": 30.0, "hit_ms": 0.05,
                        "speedup": 600.0},
        "bitwise_vs_offline": True,
    }
    payload.update(over)
    return payload


def test_serve_gate_passes_on_healthy_payload(capsys):
    assert bench_gate.gate_serve(_serve_payload())
    assert "serve gate ok" in capsys.readouterr().out


def test_serve_gate_fails_on_bitwise_mismatch(capsys):
    assert not bench_gate.gate_serve(_serve_payload(bitwise_vs_offline=False))
    assert "REGRESSION" in capsys.readouterr().out


def test_serve_gate_fails_when_cache_speedup_too_low(capsys):
    payload = _serve_payload(
        graph_cache={"cold_build_ms": 30.0, "hit_ms": 10.0, "speedup": 3.0})
    assert not bench_gate.gate_serve(payload, min_cache_speedup=5.0)
    assert "graph-cache" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# existing gates keep their contracts (smoke)


@pytest.mark.parametrize("bitwise,exact,overhead,want", [
    (True, True, 50.0, True),
    (False, True, 50.0, False),
    (True, True, 500.0, False),
])
def test_resilience_gate_matrix(bitwise, exact, overhead, want):
    payload = {"losses_bitwise_equal": bitwise, "restore_exact": exact,
               "overhead_pct": overhead, "ckpt_every": 5, "save_ms": 1.0,
               "restore_ms": 1.0, "tree_bytes": 1000}
    assert bench_gate.gate_resilience(payload, 200.0) is want
