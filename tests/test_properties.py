"""Property-style randomized sweeps over substrate invariants (hypothesis is
unavailable offline; seeded multi-draw sweeps cover the same ground)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.graph import segment
from repro.models.transformer.layers import apply_rope, rmsnorm, softcap
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw


@pytest.mark.parametrize("seed", range(5))
def test_segment_softmax_matches_dense(seed):
    rng = np.random.default_rng(seed)
    n_seg = int(rng.integers(3, 10))
    E = int(rng.integers(10, 60))
    ids = jnp.asarray(rng.integers(0, n_seg, E))
    logits = jnp.asarray(rng.normal(size=(E,)).astype(np.float32))
    out = segment.segment_softmax(logits, ids, n_seg)
    for s in range(n_seg):
        m = np.asarray(ids) == s
        if m.any():
            dense = np.exp(np.asarray(logits)[m] - np.asarray(logits)[m].max())
            dense = dense / dense.sum()
            np.testing.assert_allclose(np.asarray(out)[m], dense, rtol=1e-5)


@pytest.mark.parametrize("seed", range(3))
def test_segment_ops_linearity_and_bounds(seed):
    rng = np.random.default_rng(100 + seed)
    E, n = 40, 7
    ids = jnp.asarray(rng.integers(0, n, E))
    a = jnp.asarray(rng.normal(size=(E, 3)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(E, 3)).astype(np.float32))
    # sum is linear
    s = segment.segment_sum(a + 2 * b, ids, n)
    s2 = segment.segment_sum(a, ids, n) + 2 * segment.segment_sum(b, ids, n)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-5, atol=1e-6)
    # mean lies within [min, max] of members
    mean = np.asarray(segment.segment_mean(a, ids, n))
    for sgi in range(n):
        m = np.asarray(ids) == sgi
        if m.any():
            assert (mean[sgi] <= np.asarray(a)[m].max(0) + 1e-5).all()
            assert (mean[sgi] >= np.asarray(a)[m].min(0) - 1e-5).all()


@pytest.mark.parametrize("seed", range(3))
def test_rope_preserves_norm_and_relative_angles(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, 12, 2, 16)).astype(np.float32))
    pos = jnp.arange(12)[None]
    y = apply_rope(x, pos, theta=10000.0)
    # rotations preserve per-head norms
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(k)x> depends only on p-k
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    def dot_at(p_q, p_k):
        qq = apply_rope(q, jnp.asarray([[p_q]]), 10000.0)
        kk = apply_rope(k, jnp.asarray([[p_k]]), 10000.0)
        return float(jnp.sum(qq * kk))
    np.testing.assert_allclose(dot_at(5, 3), dot_at(9, 7), rtol=1e-4)


def test_softcap_bounds_and_identity():
    x = jnp.linspace(-1000, 1000, 101)
    y = softcap(x, 30.0)
    assert float(jnp.abs(y).max()) <= 30.0
    np.testing.assert_allclose(np.asarray(softcap(x, None)), np.asarray(x))
    # near zero it is ~identity
    small = jnp.asarray([-0.5, 0.1, 0.4])
    np.testing.assert_allclose(np.asarray(softcap(small, 50.0)),
                               np.asarray(small), rtol=1e-3)


def test_rmsnorm_scale_invariance_direction():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    p = {"g": jnp.zeros(16)}
    y1 = rmsnorm(p, x)
    y2 = rmsnorm(p, 3.7 * x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4)
    np.testing.assert_allclose(
        np.sqrt((np.asarray(y1) ** 2).mean(-1)), 1.0, rtol=1e-3)


@pytest.mark.parametrize("seed", range(2))
def test_adamw_step_bounded_by_lr(seed):
    """|update| <= ~lr per coordinate (Adam property), any gradient scale."""
    rng = np.random.default_rng(seed)
    cfg = AdamWConfig(schedule=lambda s: jnp.asarray(0.01), clip_norm=None,
                      weight_decay=0.0)
    params = {"w": jnp.asarray(rng.normal(size=8).astype(np.float32))}
    state = init_adamw(params, cfg)
    g = {"w": jnp.asarray((rng.normal(size=8)
                           * 10.0 ** float(rng.integers(-3, 4))).astype(np.float32))}
    new_p, _, _ = adamw_update(g, state, params, cfg)
    step = np.abs(np.asarray(new_p["w"] - params["w"]))
    assert (step <= 0.011 + 1e-6).all()
