"""Autoregressive rollout training (repro.train.rollout): the paper's
consistency guarantee extended to K chained forwards.

The load-bearing assertion: the K=3 rollout loss, per-step predictions AND
parameter gradients are identical between 1 rank and a 4-partition graph —
for BOTH halo/compute schedules (blocking / overlap).  Each rollout step
feeds the model its own previous prediction, so any halo inconsistency
compounds geometrically; this is the sharpest consistency test in the
suite.  The real-collective shard_map rollout is exercised by the
subprocess driver at the bottom and by the CI consistency-matrix job.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    A2A, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph, box_mesh,
    init_gnn, partition_mesh, gather_node_features, taylor_green_velocity,
)
from repro.core.partition import scatter_node_outputs
from repro.core.reference import rollout_stacked

K = 3
DT = 0.05


def _case():
    mesh = box_mesh((4, 2, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    return mesh, cfg, params


def _sequences(pg, mesh):
    x0 = jnp.asarray(gather_node_features(
        pg, taylor_green_velocity(mesh.coords)))
    tgts = jnp.stack([
        jnp.asarray(gather_node_features(
            pg, taylor_green_velocity(mesh.coords, t=(k + 1) * DT)))
        for k in range(K)])
    return x0, tgts


def _rollout(mesh, cfg, params, grid, mode, schedule, noise_global=None):
    pg = partition_mesh(mesh, grid)
    plan = NMPPlan.build(pg, mode, schedule=schedule)
    graph = ShardedGraph.build(pg, mesh.coords, plan)
    x0, tgts = _sequences(pg, mesh)
    noise = None
    if noise_global is not None:
        noise = jnp.asarray(gather_node_features(pg, noise_global))

    def f(p):
        return rollout_stacked(p, x0, tgts, graph, plan, cfg.node_out,
                               noise=noise)
    (loss, preds), grads = jax.value_and_grad(f, has_aux=True)(params)
    preds_g = np.stack([scatter_node_outputs(pg, np.asarray(preds[k]))
                        for k in range(K)])
    return float(loss), preds_g, grads


def _grad_rel_err(a, b):
    na = float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                            for x in jax.tree.leaves(a))))
    nd = float(jnp.sqrt(sum(jnp.sum(jnp.square(x - y)) for x, y in
                            zip(jax.tree.leaves(a), jax.tree.leaves(b)))))
    return nd / max(na, 1e-12)


@pytest.mark.parametrize("schedule", ["blocking", "overlap"])
@pytest.mark.parametrize("grid", [(4, 1, 1), (2, 2, 1)])
def test_rollout_consistency_1_vs_4_ranks(schedule, grid):
    """K=3 rollout: loss, per-step predictions and parameter gradients are
    identical between 1 rank and a 4-partition graph, both schedules."""
    mesh, cfg, params = _case()
    l1, p1, g1 = _rollout(mesh, cfg, params, (1, 1, 1), NONE, schedule)
    l4, p4, g4 = _rollout(mesh, cfg, params, grid, A2A, schedule)
    assert abs(l4 - l1) < 2e-6 * max(1.0, abs(l1)), (grid, schedule)
    np.testing.assert_allclose(p4, p1, rtol=3e-4, atol=1e-5)
    # K chained forwards amplify fp32 summation-order noise elementwise, so
    # gradients are compared by relative norm (loss/value agreement above is
    # the bitwise-level check)
    assert _grad_rel_err(g1, g4) < 5e-4, (grid, schedule)


def test_rollout_blocking_matches_overlap():
    """The two schedules are arithmetically identical through the K-step
    feedback loop as well."""
    mesh, cfg, params = _case()
    lb, pb, gb = _rollout(mesh, cfg, params, (2, 2, 1), A2A, "blocking")
    lo, po, go = _rollout(mesh, cfg, params, (2, 2, 1), A2A, "overlap")
    assert abs(lo - lb) < 1e-6 * max(1.0, abs(lb))
    np.testing.assert_allclose(po, pb, rtol=3e-4, atol=1e-5)
    assert _grad_rel_err(gb, go) < 5e-4


def test_rollout_without_halo_deviates():
    """Dropping the exchange breaks the K-step rollout harder than the
    single-step forward — the inconsistency is fed back K times."""
    mesh, cfg, params = _case()
    l1, _, _ = _rollout(mesh, cfg, params, (1, 1, 1), NONE, "blocking")
    ln, _, _ = _rollout(mesh, cfg, params, (2, 2, 1), NONE, "blocking")
    assert abs(ln - l1) > 1e-6


def test_pushforward_noise_consistent_and_stop_grad():
    """Pushforward noise: (a) perturbing the initial state stays 1-rank ==
    4-rank consistent when the noise is drawn on the global field, (b) the
    perturbation actually changes the loss, and (c) gradients do not flow
    through the noised state (stop_gradient): d loss / d noise == 0."""
    mesh, cfg, params = _case()
    rng = np.random.default_rng(0)
    nz = rng.normal(size=(mesh.n_nodes, cfg.node_in)).astype(np.float32) * 0.05
    l1, p1, g1 = _rollout(mesh, cfg, params, (1, 1, 1), NONE, "blocking",
                          noise_global=nz)
    l4, p4, g4 = _rollout(mesh, cfg, params, (2, 2, 1), A2A, "blocking",
                          noise_global=nz)
    assert abs(l4 - l1) < 2e-6 * max(1.0, abs(l1))
    np.testing.assert_allclose(p4, p1, rtol=3e-4, atol=1e-5)
    assert _grad_rel_err(g1, g4) < 5e-4
    # the noise engaged
    l0, _, _ = _rollout(mesh, cfg, params, (1, 1, 1), NONE, "blocking")
    assert abs(l1 - l0) > 1e-7
    # stop_gradient: the loss is insensitive to the noise argument
    pg = partition_mesh(mesh, (1, 1, 1))
    plan = NMPPlan(halo=HaloSpec(mode=NONE))
    graph = ShardedGraph.build(pg, mesh.coords, plan)
    x0, tgts = _sequences(pg, mesh)
    noise = jnp.asarray(gather_node_features(pg, nz))
    g_noise = jax.grad(lambda n: rollout_stacked(
        params, x0, tgts, graph, plan, cfg.node_out, noise=n)[0])(noise)
    assert float(jnp.abs(g_noise).max()) == 0.0


def test_rollout_gradient_flows_through_every_step():
    """BPTT sanity: a loss depending ONLY on the final step still reaches
    the parameters — gradients flow through the scan over the model's own
    predictions (no accidental stop_gradient between steps)."""
    mesh, cfg, params = _case()
    pg = partition_mesh(mesh, (1, 1, 1))
    plan = NMPPlan(halo=HaloSpec(mode=NONE))
    graph = ShardedGraph.build(pg, mesh.coords, plan)
    x0, tgts = _sequences(pg, mesh)

    def last_step_loss(p):
        _, preds = rollout_stacked(p, x0, tgts, graph, plan, cfg.node_out)
        return jnp.sum((preds[-1] - tgts[-1]) ** 2)

    g = jax.grad(last_step_loss)(params)
    gn = float(jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(g))))
    assert np.isfinite(gn) and gn > 0
    # and the K-step predictions genuinely differ from repeating step 1
    _, preds = rollout_stacked(params, x0, tgts, graph, plan, cfg.node_out)
    assert float(jnp.abs(preds[2] - preds[0]).max()) > 1e-6


def test_rollout_shard_map_collective_path_subprocess():
    """The jitted production rollout on REAL collectives (4 host devices),
    both partition grids x both halo modes, vs the stacked oracle."""
    driver = os.path.join(os.path.dirname(__file__), "drivers",
                          "rollout_driver.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, driver], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, \
        f"driver failed:\n{res.stdout[-3000:]}\n{res.stderr[-3000:]}"
    assert "ROLLOUT DRIVER PASS" in res.stdout


def test_rollout_curriculum_and_noise_annealing():
    """TrainConfig.rollout_curriculum splits the run into even stages of
    increasing K (1 -> 2 here) and pushforward_noise_final anneals the
    stop-grad noise linearly; the smoke run must record the staged K per
    step and produce finite losses on the single-device mesh."""
    from repro.launch.mesh import make_mesh
    from repro.train.loop import TrainConfig, train_consistent_gnn

    mesh = box_mesh((2, 2, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=1, mlp_hidden_layers=2)
    pg = partition_mesh(mesh, (1, 1, 1))
    mesh_dev = make_mesh((1, 1), ("data", "graph"))
    tcfg = TrainConfig(n_steps=4, batch=1, halo_mode="none", log_every=100,
                       rollout_curriculum=(1, 2),
                       pushforward_noise=0.01, pushforward_noise_final=0.0)
    hist = train_consistent_gnn(mesh_dev, pg, mesh, cfg, tcfg)
    assert hist["rollout_k"] == [1, 1, 2, 2]
    assert all(np.isfinite(loss) for loss in hist["losses"])
    assert hist["schedule"] == "blocking"
